"""Static-analysis benchmark: contract-check wall time and collective-byte
budget headroom per hot path.

The contract layer is itself on the CI critical path, so its cost is a
budget too: this entry records how long each hot-path lowering + audit
takes on the host mesh grid, and how much of the declared per-pivot
collective-byte budget ``pq_step`` actually uses (headroom shrinking
toward 1.0 over PRs = traffic creep the byte model didn't price in).

Results land in ``results/analysis.json`` (the same report the CLI
writes, refreshed with grid='host' records).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit


def run(full: bool = False):
    from repro.analysis.contracts import run_contracts

    violations, records, wall_s = run_contracts("host")
    emit("analysis/contracts_total", wall_s * 1e6,
         f"hot_paths={len(records)};violations={len(violations)}")
    for rec in records:
        name = rec["hot_path"].replace("@", "_")
        derived = []
        if "budget_used_frac" in rec:
            derived.append(f"budget_used={rec['budget_used_frac']:.3f}")
        coll = rec.get("collective_bytes", {})
        if coll:
            derived.append(f"coll_bytes={coll.get('total', 0.0):.3e}")
        dense = rec.get("dense_passes")
        if dense is not None:
            derived.append(f"dense={dense['top']}+{dense['cond']}c")
        emit(f"analysis/{name}", rec["wall_s"] * 1e6, ";".join(derived))

    os.makedirs("results", exist_ok=True)
    out = {"grid": "host", "wall_s": round(wall_s, 3),
           "violations": [v.format() for v in violations],
           "hot_paths": records}
    # refresh the CLI's report in place when it exists (keep lint/baseline
    # sections from the last full run), else write a contracts-only one
    path = os.path.join("results", "analysis.json")
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            prev["contracts"] = {"violations": out["violations"],
                                 "hot_paths": records}
            prev.setdefault("wall_s", {})["contracts_bench"] = out["wall_s"]
            out = prev
        except (ValueError, KeyError):
            pass  # unreadable report: overwrite with the fresh records
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    assert not violations, "\n".join(out["violations"])
