"""Paper Mini-Experiment 3 (App. C): Parallel Dual Simplex behaviour.

This container has one CPU core, so OpenMP-style core-count speedups are
not measurable; we report the quantities the TPU port is built around:

  * per-iteration wall time vs n (pricing + BFRT are O(n) vectorised),
  * BFRT long-step size: bound flips absorbed by the FIRST iteration
    (paper: ~n/2 single-step equivalents),
  * total simplex iterations to optimality (tiny, thanks to BFRT),
  * per-device collective bytes of the distributed pq_step (from the
    multi-pod dry-run artifacts, when present): O(num_buckets), not O(n).
"""
from __future__ import annotations

import glob
import json

import numpy as np

from benchmarks.common import emit, timed
from repro.core.lp import solve_lp_np


def run(full: bool = False):
    rng = np.random.default_rng(0)
    sizes = (10_000, 100_000, 1_000_000) if full else (10_000, 100_000)
    for n in sizes:
        c = rng.normal(size=n)
        A = np.stack([np.ones(n), rng.normal(14, 1.5, n),
                      rng.normal(10, 2.0, n)])
        E = 30
        bl = np.array([15.0, 14 * E - 9, -np.inf])
        bu = np.array([45.0, 14 * E + 9, 10 * E + 8])
        res, t = timed(solve_lp_np, c, A, bl, bu, np.ones(n))
        emit(f"miniexp3/pds/n{n}", t / max(res.iters, 1) * 1e6,
             f"iters={res.iters};status={res.status}")
    # BFRT long-step: flips in the first iteration
    n = 100_000
    c = -np.abs(rng.normal(size=n))       # maximize-like: everything wants up
    A = np.stack([rng.normal(14, 1.5, n)])
    bl = np.array([-np.inf])
    bu = np.array([14.0 * n * 0.5])       # forces ~half the vars to flip
    res, _ = timed(solve_lp_np, c, A, bl, bu, np.ones(n))
    emit("miniexp3/bfrt_longstep/n100000", 0.0,
         f"iters={res.iters};support={int((res.x > 0).sum())}")
    # distributed pq_step collective bytes (from dry-run artifacts)
    for f in sorted(glob.glob("results/dryrun/pq_step__*.json")):
        rec = json.load(open(f))
        if rec.get("status") == "OK":
            emit(f"miniexp3/pq_step/{rec['mesh']}", 0.0,
                 f"coll_bytes={rec['collectives'].get('total', 0):.3e};"
                 f"dot_flops={rec['dot_flops']:.3e}")
