"""Paper Fig. 7: ratio score z of DLV / 1-D DLV / KD-tree at matched
downscale factors on N(0,1), 1e5 samples."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core.dlv import dlv, dlv_1d_partition, ratio_score
from repro.core.kdtree import kdtree_partition


def run(full: bool = False):
    n = 100_000 if full else 30_000
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, 1))
    vals = np.sort(X[:, 0])
    for d_f in (10, 100, 1000):
        res, t = timed(dlv, X, d_f)
        z = ratio_score(X[:, 0], res.gid)
        emit(f"fig7/dlv/df{d_f}", t * 1e6,
             f"z={z:.3e};groups={res.num_groups}")
        # 1-D DLV at beta targeting the same group count
        beta = 13.5 * np.var(vals) / d_f ** 2
        gid, _ = dlv_1d_partition(vals, beta)
        z1 = ratio_score(vals, gid)
        emit(f"fig7/dlv1d/df{d_f}", 0.0,
             f"z={z1:.3e};groups={int(gid.max()) + 1}")
        kd, t_kd = timed(kdtree_partition, X, tau=max(2, d_f))
        z_kd = ratio_score(X[:, 0], kd.gid)
        emit(f"fig7/kdtree/df{d_f}", t_kd * 1e6,
             f"z={z_kd:.3e};groups={kd.num_groups}")
