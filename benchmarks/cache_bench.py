"""Cross-query cache benchmark — acceptance instrument for the
``repro.core.qcache`` cross-query artifact cache (ROADMAP
"cross-query partition cache").

Runs a flight of overlapping query variants against one engine+cache and
records cold-vs-warm latency, hit kinds and parity:

* **repeat** — the same query twice: second solve must serve the
  validated cached package (exact hit) at >= 3x end-to-end speedup with
  an identical package;
* **tightened** — a contained variant (higher hardness => every interval
  nested): shortcut-to-DR over the cached layer-0 candidate set, warm-
  started from the cached lp1 basis; must beat a cold engine on the same
  query and (on this deterministic flight) return the identical package;
* **widened** — a looser variant (NOT contained): must miss;
* **disjoint** — a different template: must miss;
* **artifact-only** — ``QCache(reuse_packages=False)``: the repeat solve
  re-runs Dual Reducer over cached candidates (no package fast path) and
  must still return the identical package.

Results land in ``BENCH_cache.json`` at the repo root (same pattern as
``BENCH_outofcore.json``).

CLI (the smoke profile is wired into CI):

    python -m benchmarks.cache_bench --smoke    # ~6e4 rows; asserts + JSON
    python -m benchmarks.cache_bench --full     # 1e6-row acceptance run
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.engine import PackageQueryEngine
from repro.core.hardness import Q2_TPCH, Q4_TPCH, column_stats, instantiate
from repro.core.qcache import QCache
from repro.data.synth_tables import make_table

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_cache.json"
ATTRS = ["price", "quantity", "discount", "tax"]


def _solve(eng, query, ilp_kwargs):
    t0 = time.perf_counter()
    res = eng.solve(query, ilp_kwargs=ilp_kwargs)
    return res, time.perf_counter() - t0


def _pkg(res):
    """Canonical (idx, mult) view for parity comparison."""
    order = np.argsort(res.idx, kind="stable")
    return np.asarray(res.idx)[order], np.asarray(res.mult)[order]


def _same_package(a, b) -> bool:
    ia, ma = _pkg(a)
    ib, mb = _pkg(b)
    return np.array_equal(ia, ib) and np.array_equal(ma, mb)


def run(full: bool = False) -> dict:
    n = 1_000_000 if full else 30_000
    alpha = 20_000 if full else 1_500
    d_f = 50 if full else 20
    ilp_kw = dict(max_nodes=200, time_limit_s=60)

    table = make_table("tpch", n, seed=1)
    stats = column_stats(table, ATTRS)
    q_prime = instantiate(Q2_TPCH, stats, 2.0)
    q_tight = instantiate(Q2_TPCH, stats, 3.0)   # contained in q_prime
    q_wide = instantiate(Q2_TPCH, stats, 1.0)    # NOT contained
    q_disj = instantiate(Q4_TPCH, stats, 2.0)    # different template
    assert q_tight.signature().contained_in(q_prime.signature())
    assert not q_wide.signature().contained_in(q_prime.signature())

    cache = QCache()
    eng = PackageQueryEngine(table, ATTRS, d_f=d_f, alpha=alpha, seed=0,
                             cache=cache)
    eng.partition()
    entry = {"n": n, "alpha": alpha, "d_f": d_f, "full": bool(full)}

    # ---- repeat flight: exact hit, validated package fast path
    r_cold, t_cold = _solve(eng, q_prime, ilp_kw)
    r_warm, t_warm = _solve(eng, q_prime, ilp_kw)
    assert r_cold.feasible and r_warm.feasible, (r_cold.status,
                                                 r_warm.status)
    assert "cached=package" in r_warm.status, r_warm.status
    assert _same_package(r_cold, r_warm), "repeat parity violated"
    repeat_speedup = t_cold / max(t_warm, 1e-9)
    assert repeat_speedup >= 3.0, \
        f"repeat speedup {repeat_speedup:.1f}x < 3x"
    entry["repeat"] = {"cold_s": round(t_cold, 5),
                       "warm_s": round(t_warm, 5),
                       "speedup": round(repeat_speedup, 1),
                       "parity": True}
    print(f"repeat,{t_warm * 1e6:.0f},speedup={repeat_speedup:.0f}x "
          f"cold={t_cold * 1e3:.1f}ms", flush=True)

    # ---- tightened flight: contained hit, shortcut-to-DR pre-prune
    r_tight, t_tight = _solve(eng, q_tight, ilp_kw)
    eng_ref = PackageQueryEngine(table, ATTRS, d_f=d_f, alpha=alpha,
                                 seed=0)
    eng_ref.partition()
    r_tref, t_tref = _solve(eng_ref, q_tight, ilp_kw)
    assert r_tight.feasible and r_tref.feasible, (r_tight.status,
                                                  r_tref.status)
    # parity is unconditional: an accepted prune must match the cold
    # answer here (deterministic flight), and a gap-rejected prune falls
    # back to a bit-identical cold descent
    assert _same_package(r_tight, r_tref), "tightened parity violated"
    pruned = "cached=contained" in r_tight.status
    if not full:
        # the smoke profile is sized so the prune passes the gap gate —
        # this is the CI gate for the contained/pre-prune path itself
        assert pruned, r_tight.status
    tight_speedup = t_tref / max(t_tight, 1e-9)
    if pruned:
        assert t_tight < t_tref, \
            f"tightened not faster: {t_tight:.4f}s vs cold {t_tref:.4f}s"
    entry["tightened"] = {"cached_s": round(t_tight, 5),
                          "cold_s": round(t_tref, 5),
                          "speedup": round(tight_speedup, 1),
                          "prune_accepted": pruned,
                          "pruned_lps": r_tight.report.cache_pruned_lps,
                          "parity": True}
    print(f"tightened,{t_tight * 1e6:.0f},speedup={tight_speedup:.1f}x "
          f"pruned={pruned} "
          f"pruned_lps={r_tight.report.cache_pruned_lps}", flush=True)

    # ---- widened + disjoint flights: both must miss (cold path)
    r_wide, t_wide = _solve(eng, q_wide, ilp_kw)
    r_disj, t_disj = _solve(eng, q_disj, ilp_kw)
    assert "cached" not in r_wide.status, r_wide.status
    assert "cached" not in r_disj.status, r_disj.status
    entry["widened"] = {"s": round(t_wide, 5), "hit": False}
    entry["disjoint"] = {"s": round(t_disj, 5), "hit": False,
                         "feasible": bool(r_disj.feasible)}
    print(f"widened,{t_wide * 1e6:.0f},miss", flush=True)
    print(f"disjoint,{t_disj * 1e6:.0f},miss", flush=True)

    # ---- artifact-only mode: no package fast path, still exact parity
    cache_art = QCache(reuse_packages=False)
    eng_art = PackageQueryEngine(table, ATTRS, d_f=d_f, alpha=alpha,
                                 seed=0, cache=cache_art)
    eng_art.partition()
    r_ac, t_ac = _solve(eng_art, q_prime, ilp_kw)
    r_aw, t_aw = _solve(eng_art, q_prime, ilp_kw)
    assert "cached=exact" in r_aw.status, r_aw.status
    assert _same_package(r_ac, r_aw), "artifact-mode parity violated"
    entry["artifact_only"] = {"cold_s": round(t_ac, 5),
                              "warm_s": round(t_aw, 5),
                              "speedup": round(t_ac / max(t_aw, 1e-9), 1),
                              "parity": True}
    print(f"artifact_only,{t_aw * 1e6:.0f},"
          f"speedup={t_ac / max(t_aw, 1e-9):.1f}x", flush=True)

    # ---- cache health
    assert cache.stats.hit_rate() > 0, cache.stats.as_dict()
    entry["cache_stats"] = cache.stats.as_dict()
    entry["hit_rate"] = round(cache.stats.hit_rate(), 3)
    print(f"hit_rate,0,{entry['hit_rate']} "
          f"stores={cache.stats.stores} bytes={cache.stats.bytes}",
          flush=True)

    data = {}
    if BENCH_PATH.exists():
        data = json.loads(BENCH_PATH.read_text())
    data["smoke" if not full else "full"] = entry
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"# wrote {BENCH_PATH}", flush=True)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast profile (CI gate)")
    ap.add_argument("--full", action="store_true",
                    help="1e6-row acceptance run")
    args = ap.parse_args()
    run(full=args.full and not args.smoke)


if __name__ == "__main__":
    main()
