"""Out-of-core pipeline benchmark — the acceptance instrument for the
streamed-relation refactor (ROADMAP "Out-of-core layer 0").

Measures, against an on-disk memmap relation that is never materialised:

* **build I/O passes** — ``dlv_bucketed`` through a ``CountingSource`` at
  two memory budgets (different bucket counts): the pass count must be
  O(1), independent of the bucket count (the seed rescanned the relation
  once per bucket);
* **peak resident rows** — the relation-level materialisation high-water
  mark across hierarchy build and end-to-end solve (candidate/chunk-sized
  only);
* **end-to-end solve time** and memmap-vs-in-memory answer parity.

Results land in ``BENCH_outofcore.json`` at the repo root (same pattern
as ``BENCH_lp.json`` / ``BENCH_partition.json``).

CLI (the smoke profile is wired into CI):

    python -m benchmarks.outofcore --smoke    # ~1e5 rows; asserts + JSON
    python -m benchmarks.outofcore            # 1e7-row acceptance run
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import relation as relation_mod
from repro.core.bucketing import MemmapSource, dlv_bucketed
from repro.core.engine import PackageQueryEngine
from repro.core.hardness import TEMPLATES, column_stats, instantiate
from repro.core.relation import CountingSource, MemmapRelation
from repro.data.synth_tables import make_table

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_outofcore.json"
ATTRS = ["price", "quantity", "discount", "tax"]


def _write_relation(n: int, seed: int, dir_: str) -> str:
    """Synthesize the TPC-H style table chunk-wise into an on-disk .npy."""
    path = os.path.join(dir_, f"relation_{n}.npy")
    mm = np.lib.format.open_memmap(path, mode="w+", dtype=np.float64,
                                   shape=(n, len(ATTRS)))
    step = 1 << 20
    table = make_table("tpch", min(n, step), seed=seed)
    # repro: allow[REPRO005] memmap seeding: block is bounded at 1<<20 rows
    block = np.stack([table[a] for a in ATTRS], axis=1)
    for a in range(0, n, step):
        b = min(a + step, n)
        mm[a:b] = block[:b - a]
        if b - a == step:                      # vary blocks cheaply
            rng = np.random.default_rng(seed + 1 + a // step)
            block = block[rng.permutation(len(block))]
    mm.flush()
    del mm
    return path


def _build_entry(path: str, shape, memory_rows: int, chunk_rows: int,
                 d_f: int) -> dict:
    src = CountingSource(MemmapSource(path, shape))
    t0 = time.time()
    part = dlv_bucketed(src, d_f, memory_rows=memory_rows,
                        chunk_rows=chunk_rows)
    dt = time.time() - t0
    root_bounds = int(part.tree.bound_off[1] - part.tree.bound_off[0])
    return {"memory_rows": memory_rows, "chunk_rows": chunk_rows,
            "passes": src.passes, "rows_read": src.rows_read,
            "n_buckets": root_bounds + 1, "groups": part.num_groups,
            "build_s": round(dt, 3)}


def run(full: bool = False, out_dir: str = "") -> dict:
    n = 10_000_000 if full else 120_000
    memory_rows = 2_000_000 if full else 20_000
    chunk_rows = 500_000 if full else 10_000
    alpha = 100_000 if full else 2_000
    d_f = 100 if full else 20
    tmp = out_dir or tempfile.mkdtemp(prefix="pq_outofcore_")
    os.makedirs(tmp, exist_ok=True)
    path = _write_relation(n, 0, tmp)
    shape = (n, len(ATTRS))
    entry = {"n": n, "attrs": ATTRS, "d_f": d_f, "alpha": alpha,
             "full": bool(full)}

    # ---- build: O(1) streaming passes, independent of the bucket count
    few = _build_entry(path, shape, memory_rows, chunk_rows, d_f)
    many = _build_entry(path, shape, memory_rows // 4, chunk_rows, d_f)
    assert many["n_buckets"] > few["n_buckets"], \
        (many["n_buckets"], few["n_buckets"])
    assert many["passes"] <= few["passes"] + 2 <= 12, (many, few)
    assert many["passes"] < many["n_buckets"] + 2, \
        "passes scaled with bucket count"
    entry["build"] = {"few_buckets": few, "many_buckets": many,
                      "passes_independent_of_buckets": True}
    print(f"build,{few['build_s'] * 1e6:.0f},"
          f"passes={few['passes']}/{many['passes']} "
          f"buckets={few['n_buckets']}/{many['n_buckets']}", flush=True)

    # ---- end-to-end solve over the memmap relation
    rel = MemmapRelation.from_npy(path, ATTRS, chunk_rows=chunk_rows)
    # query hardness stats from ONE sorted sample gather, not full columns
    sample = np.sort(np.random.default_rng(1).choice(
        n, min(n, 200_000), replace=False))
    table_stats = column_stats(rel.gather_rows(sample, tuple(ATTRS)), ATTRS)
    query = instantiate(TEMPLATES["Q2_TPCH"], table_stats, 3)
    eng = PackageQueryEngine(rel, ATTRS, d_f=d_f, alpha=alpha, seed=0,
                             memory_rows=memory_rows,
                             chunk_rows=chunk_rows)
    relation_mod.reset_peak_resident()
    t0 = time.time()
    eng.partition()
    t_build = time.time() - t0
    build_peak = relation_mod.peak_resident_rows()
    relation_mod.reset_peak_resident()
    t0 = time.time()
    res = eng.solve(query, ilp_kwargs=dict(max_nodes=200, time_limit_s=60))
    t_solve = time.time() - t0
    solve_peak = relation_mod.peak_resident_rows()
    assert res.feasible, res.status
    assert solve_peak <= 2 * alpha, (solve_peak, alpha)
    assert build_peak < n, (build_peak, n)
    assert query.check_package(rel, res.idx, res.mult)
    entry["solve"] = {
        "hierarchy_build_s": round(t_build, 3),
        "solve_s": round(t_solve, 3),
        "build_peak_resident_rows": int(build_peak),
        "solve_peak_resident_rows": int(solve_peak),
        "layers": [int(l.size) for l in eng.hierarchy.layers],
        "objective": float(res.obj), "package_size": int(res.mult.sum()),
        "status": res.status,
    }
    print(f"solve,{t_solve * 1e6:.0f},obj={res.obj:.2f} "
          f"peak={solve_peak}rows layers={entry['solve']['layers']}",
          flush=True)

    # ---- parity vs the in-memory engine: identical per-layer backends by
    # construction (bucketing at layer 0, dlv above — the streamed mix)
    if not full:
        table = {a: np.array(rel.X[:, j]) for j, a in enumerate(ATTRS)}
        eng_mem = PackageQueryEngine(table, ATTRS, d_f=d_f, alpha=alpha,
                                     seed=0, memory_rows=memory_rows,
                                     chunk_rows=chunk_rows,
                                     layer0_backend="bucketing")
        res_mem = eng_mem.solve(query, ilp_kwargs=dict(max_nodes=200,
                                                       time_limit_s=60))
        assert res_mem.feasible
        assert abs(res_mem.obj - res.obj) <= 1e-9 * max(1, abs(res.obj)), \
            (res_mem.obj, res.obj)
        assert np.array_equal(res_mem.idx, res.idx)
        entry["parity"] = {"in_memory_obj": float(res_mem.obj),
                           "match": True}
        print(f"parity,0,obj_match={res_mem.obj == res.obj}", flush=True)

    data = {}
    if BENCH_PATH.exists():
        data = json.loads(BENCH_PATH.read_text())
    data["smoke" if not full else "full"] = entry
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"# wrote {BENCH_PATH}", flush=True)
    if not out_dir:
        import shutil
        shutil.rmtree(tmp, ignore_errors=True)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast profile (CI gate)")
    ap.add_argument("--full", action="store_true",
                    help="1e7-row acceptance run")
    ap.add_argument("--out-dir", default="",
                    help="keep the generated relation here")
    args = ap.parse_args()
    run(full=args.full and not args.smoke, out_dir=args.out_dir)


if __name__ == "__main__":
    main()
