"""Paper Mini-Experiment 5: DLV vs KD-tree partitioning a large relation
(time + achievable group counts).  Container scale: 3e5-1e6 tuples
(paper: 1e8-1e9 on 80 cores; KD-tree OOMs at 1e9)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core.dlv import dlv
from repro.core.kdtree import kdtree_partition
from repro.data.synth_tables import make_table


def run(full: bool = False):
    n = 1_000_000 if full else 300_000
    table = make_table("tpch", n, seed=0)
    X = np.stack([table[a] for a in
                  ("price", "quantity", "discount", "tax")], axis=1)
    res, t_dlv = timed(dlv, X, 100)
    emit(f"miniexp5/dlv/n{n}", t_dlv * 1e6,
         f"groups={res.num_groups};target={n // 100}")
    kd, t_kd = timed(kdtree_partition, X, tau=max(2, n // 1000))
    emit(f"miniexp5/kdtree/n{n}", t_kd * 1e6,
         f"groups={kd.num_groups};target=1000")
