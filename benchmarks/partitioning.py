"""Paper Mini-Experiment 5, driven through the Partitioner subsystem: the
batched-frontier DLV build (``dlv_rounds``) vs the seed heap build
(``dlv_heap``) vs KD-tree, at matched group counts.

Records build-time / ratio-score results — including the round-by-round
build trajectory and the batch-vs-scalar GetGroup probe parity check — to
``BENCH_partition.json`` at the repo root so later PRs can track the
trajectory (same pattern as ``BENCH_lp.json``).

CLI (also wired into CI):

    python -m benchmarks.partitioning --smoke    # fast; asserts quality
    python -m benchmarks.partitioning --full     # 5M-tuple acceptance run
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit, timed
from repro.core.dlv import dlv_heap, dlv_rounds, ratio_score
from repro.core.hierarchy import _min_gap
from repro.core.kdtree import kdtree_partition
from repro.data.synth_tables import make_table

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_partition.json"
ATTRS = ("price", "quantity", "discount", "tax")

# quality bar asserted by the CI smoke: WEIGHTED ratio score (within-group
# variance fraction, in [0,1]) on the max-variance attribute — the one DLV
# actually splits (beta is keyed by the dominant attribute, so the others
# legitimately stay near 1.0 and only the dominant score measures quality)
SMOKE_RATIO_MAX = 0.05


def _mean_ratio(X: np.ndarray, gid: np.ndarray) -> float:
    return float(np.mean([ratio_score(X[:, j], gid, weighted=True)
                          for j in range(X.shape[1])]))


def _dominant_ratio(X: np.ndarray, gid: np.ndarray) -> float:
    j = int(np.argmax(X.var(axis=0)))
    return ratio_score(X[:, j], gid, weighted=True)


def _probe_parity(res, X: np.ndarray, probes: int, seed: int = 1) -> dict:
    """Batch GetGroup == scalar descent on random probes, plus timings."""
    rng = np.random.default_rng(seed)
    T = X[rng.choice(len(X), size=min(probes, len(X)), replace=False)]
    t0 = time.time()
    batch = res.get_group_batch(T)
    t_batch = time.time() - t0
    t0 = time.time()
    scalar = np.fromiter((res.get_group(t) for t in T), np.int64, len(T))
    t_scalar = time.time() - t0
    assert np.array_equal(batch, scalar), \
        "batch get_group diverged from scalar descent"
    return {"probes": int(len(T)), "match": True,
            "t_batch_s": t_batch, "t_scalar_s": t_scalar,
            "speedup": t_scalar / max(t_batch, 1e-9)}


def build_entry(n: int, d_f: int, *, heap: bool = True,
                seed_heap_budget_s: float = 0.0,
                probes: int = 10_000, seed: int = 0) -> dict:
    """One benchmark entry: rounds (+trajectory), optional heap baseline
    (fast shared-scan variant, plus the faithful seed-scan variant under a
    time budget when ``seed_heap_budget_s`` > 0), KD-tree at matched group
    count, and the probe parity record."""
    table = make_table("tpch", n, seed=seed)
    # repro: allow[REPRO005] in-memory baseline arm by design
    X = np.stack([table[a] for a in ATTRS], axis=1)
    entry = {"n": n, "d_f": d_f, "target": n // d_f}

    log: list = []
    res_r, t_r = timed(dlv_rounds, X, d_f, log=log)
    entry["rounds"] = {"time_s": t_r, "groups": res_r.num_groups,
                       "ratio_score": _mean_ratio(X, res_r.gid),
                       "ratio_score_dominant": _dominant_ratio(X, res_r.gid),
                       "trajectory": log}
    emit(f"miniexp5/dlv_rounds/n{n}", t_r * 1e6,
         f"groups={res_r.num_groups};z={entry['rounds']['ratio_score']:.4f}")

    if heap:
        res_h, t_h = timed(dlv_heap, X, d_f)
        entry["heap"] = {"time_s": t_h, "groups": res_h.num_groups,
                         "ratio_score": _mean_ratio(X, res_h.gid),
                         "ratio_score_dominant": _dominant_ratio(X, res_h.gid)}
        entry["speedup_vs_heap"] = t_h / max(t_r, 1e-9)
        emit(f"miniexp5/dlv_heap/n{n}", t_h * 1e6,
             f"groups={res_h.num_groups};"
             f"z={entry['heap']['ratio_score']:.4f};"
             f"speedup={entry['speedup_vs_heap']:.1f}x")

    if seed_heap_budget_s > 0:
        # the SEED build: shape-polymorphic jitted scan (one XLA compile
        # per distinct span length) — run under a budget; a timeout makes
        # the recorded speedup a lower bound
        t0 = time.time()
        try:
            res_s = dlv_heap(X, d_f, scan="seed",
                             time_budget_s=seed_heap_budget_s)
            t_s = time.time() - t0
            entry["seed_heap"] = {"time_s": t_s,
                                  "groups": res_s.num_groups,
                                  "ratio_score": _mean_ratio(X, res_s.gid),
                                  "timed_out": False}
        except TimeoutError as e:
            t_s = time.time() - t0
            entry["seed_heap"] = {"time_s": t_s, "timed_out": True,
                                  "detail": str(e)}
        entry["speedup_vs_seed_heap"] = t_s / max(t_r, 1e-9)
        entry["speedup_vs_seed_heap_is_lower_bound"] = \
            entry["seed_heap"]["timed_out"]
        emit(f"miniexp5/dlv_seed_heap/n{n}", t_s * 1e6,
             f"timed_out={entry['seed_heap']['timed_out']};"
             f"speedup={entry['speedup_vs_seed_heap']:.1f}x")

    tau = max(2, n // max(res_r.num_groups, 1))
    kd, t_kd = timed(kdtree_partition, X, tau=tau)
    entry["kdtree"] = {"time_s": t_kd, "groups": kd.num_groups,
                       "ratio_score": _mean_ratio(X, kd.gid)}
    emit(f"miniexp5/kdtree/n{n}", t_kd * 1e6,
         f"groups={kd.num_groups};z={entry['kdtree']['ratio_score']:.4f}")

    entry["get_group"] = _probe_parity(res_r, X, probes)
    emit(f"miniexp5/get_group_batch/n{n}",
         entry["get_group"]["t_batch_s"] * 1e6,
         f"probes={entry['get_group']['probes']};"
         f"speedup={entry['get_group']['speedup']:.1f}x")
    return entry


def bench_min_gap(n: int = 3_000_000, k: int = 4) -> dict:
    """Satellite: sampled _min_gap estimate vs the exact path."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, k))
    est, t_sample = timed(_min_gap, X)                      # n > exact limit
    exact, t_exact = timed(_min_gap, X, exact_limit=n + 1)  # force exact
    emit(f"miniexp5/min_gap/n{n}", t_sample * 1e6,
         f"exact_us={t_exact * 1e6:.0f};ratio={est / exact:.2f}")
    return {"n": n, "t_sample_s": t_sample, "t_exact_s": t_exact,
            "estimate_over_exact": est / exact}


def _save(update: dict) -> None:
    data = {}
    if BENCH_PATH.exists():
        data = json.loads(BENCH_PATH.read_text())
    entries = data.setdefault("entries", {})
    for key, val in update.get("entries", {}).items():
        entries[key] = val
    for key in ("min_gap",):
        if key in update:
            data[key] = update[key]
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"# wrote {BENCH_PATH}")


def run(full: bool = False):
    n = 1_000_000 if full else 300_000
    entry = build_entry(n, 100, heap=True)
    # 3M rows in both profiles: _min_gap's sampled path only engages above
    # its 2M exact limit
    update = {"entries": {f"n{n}_df100": entry},
              "min_gap": bench_min_gap(3_000_000)}
    if full:
        # acceptance run: 5M tuples, k=4, d_f=100 (paper-scale container
        # run); the seed build gets 30 min before the speedup becomes a
        # lower bound
        big = build_entry(5_000_000, 100, heap=True,
                          seed_heap_budget_s=1800.0)
        update["entries"]["n5000000_df100"] = big
    _save(update)


def smoke():
    """CI gate: fast build + parity; asserts the JSON lands and the
    round-based build's quality is under the bar."""
    entry = build_entry(60_000, 100, heap=False, probes=5_000)
    _save({"entries": {"smoke_n60000_df100": entry}})
    assert BENCH_PATH.exists(), "BENCH_partition.json was not written"
    z = entry["rounds"]["ratio_score_dominant"]
    assert z < SMOKE_RATIO_MAX, f"ratio score {z} over bar {SMOKE_RATIO_MAX}"
    assert entry["get_group"]["match"]
    print(f"# smoke OK: z={z:.4f} groups={entry['rounds']['groups']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        run(full=args.full)


if __name__ == "__main__":
    main()
