"""Paper Fig. 8: query performance as relation size increases —
Progressive Shading vs SketchRefine vs direct B&B ("Gurobi" role).

Container scale: 5e3 - 1e5 tuples (the paper's 1e6-1e9 on 80 cores);
the shapes of interest are the relative curves: PS stays fast and feasible,
SR degrades, direct ILP blows up.
"""
from __future__ import annotations


from benchmarks.common import ILP_KW, build_engine, emit, gap, query_for, timed


def run(full: bool = False):
    sizes = [5_000, 20_000, 80_000] if not full else [5_000, 20_000,
                                                      80_000, 300_000]
    for kind, tmpl in (("sdss", "Q1_SDSS"), ("tpch", "Q2_TPCH")):
        for n in sizes:
            eng = build_engine(kind, n)
            _, t_part = timed(eng.partition)
            emit(f"fig8/partition/{kind}/n{n}", t_part * 1e6,
                 f"layers={[l.size for l in eng.hierarchy.layers]}")
            for h in (1, 5):
                q = query_for(eng, tmpl, h)
                lp = eng.lp_bound(q)
                ps, t_ps = timed(eng.solve, q, ilp_kwargs=ILP_KW)
                emit(f"fig8/ps/{kind}/n{n}/h{h}", t_ps * 1e6,
                     f"feasible={ps.feasible};gap={gap(ps, lp):.4f}")
                if n <= 20_000:
                    sr, t_sr = timed(eng.solve_sketchrefine, q,
                                     ilp_kwargs=ILP_KW)
                    emit(f"fig8/sketchrefine/{kind}/n{n}/h{h}", t_sr * 1e6,
                         f"feasible={sr.feasible};gap={gap(sr, lp):.4f}")
                if n <= 20_000:
                    bb, t_bb = timed(eng.solve_direct, q, ILP_KW)
                    emit(f"fig8/direct_ilp/{kind}/n{n}/h{h}", t_bb * 1e6,
                         f"feasible={bb.feasible};gap={gap(bb, lp):.4f}")
