"""End-to-end training driver: package-query data selection + training with
checkpointing on a ~100M-class config.

Default runs a reduced model for a few hundred steps on this CPU container;
pass --full-135m to train the real smollm-135m config (slow on CPU, the
config a pod would run).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full-135m]
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-135m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    arch = "smollm-135m" if args.full_135m else "smollm-135m-smoke"
    batch = "8" if args.full_135m else "16"
    seq = "512" if args.full_135m else "128"
    losses = train_main([
        "--arch", arch,
        "--steps", str(args.steps),
        "--batch", batch,
        "--seq", seq,
        "--lr", "3e-3",
        "--select-data",                 # package-query data selection
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "50",
        "--log-every", "20",
    ])
    print(f"[example] final loss {losses[-1]:.4f} "
          f"(improved {losses[0] - losses[-1]:+.4f})")


if __name__ == "__main__":
    main()
