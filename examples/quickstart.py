"""Quickstart: answer a package query over a synthetic relation with
Progressive Shading, and compare against the direct ILP.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import PackageQueryEngine
from repro.core.paql import Constraint, PackageQuery


def main():
    rng = np.random.default_rng(0)
    n = 50_000
    # A relation of products: value, weight, volume
    table = {
        "value": rng.lognormal(3.0, 0.6, n),
        "weight": rng.uniform(0.2, 9.0, n),
        "volume": rng.uniform(0.1, 4.0, n),
    }

    # SELECT PACKAGE(*) FROM products REPEAT 0
    # SUCH THAT 10 <= COUNT(*) <= 30
    #       AND SUM(weight) <= 60 AND SUM(volume) BETWEEN 18 AND 22
    # MAXIMIZE SUM(value)
    query = PackageQuery(
        objective_attr="value", maximize=True,
        constraints=(
            Constraint(None, 10, 30),
            Constraint("weight", hi=60.0),
            Constraint("volume", lo=18.0, hi=22.0),
        ))

    eng = PackageQueryEngine(table, ["value", "weight", "volume"],
                             d_f=25, alpha=2500, seed=0)
    eng.partition()
    print(f"hierarchy: {[l.size for l in eng.hierarchy.layers]} "
          f"(partitioned in {eng.partition_time_s:.1f}s)")

    res = eng.solve(query)
    assert res.feasible and query.check_package(table, res.idx, res.mult)
    lp = eng.lp_bound(query)
    print(f"Progressive Shading: {int(res.mult.sum())} tuples, "
          f"value={res.obj:.1f} (LP bound {lp:.1f}, "
          f"gap {(lp + .1) / (res.obj + .1):.4f})  [{res.status}]")
    print(f"  weight={table['weight'][res.idx] @ res.mult:.1f} <= 60, "
          f"volume={table['volume'][res.idx] @ res.mult:.2f} in [18, 22]")

    direct = eng.solve_direct(query, dict(max_nodes=300, time_limit_s=30))
    if direct.feasible:
        print(f"Direct ILP (black-box role): value={direct.obj:.1f}")


if __name__ == "__main__":
    main()
