"""Out-of-core package query: solve over an on-disk memmap relation that
is never loaded into memory.

Writes a ~1M-row relation to disk chunk-by-chunk, wraps it in a
``MemmapRelation``, and runs the full Progressive Shading pipeline on it:
layer 0 is partitioned through the Appendix D.2 bucketing backend under a
``memory_rows`` budget, the shading cascade passes candidate ids down, and
Dual Reducer / validation gather only the <= alpha candidate rows.  The
peak relation-resident row count is printed at the end — it stays at
candidate/chunk scale, not the relation's.

    PYTHONPATH=src python examples/outofcore_query.py
"""
import os
import tempfile

import numpy as np

from repro.core import relation as relation_mod
from repro.core.engine import PackageQueryEngine
from repro.core.paql import Constraint, PackageQuery
from repro.core.relation import MemmapRelation

ATTRS = ["value", "weight", "volume"]


def write_relation(path: str, n: int, chunk: int = 1 << 18) -> None:
    """Stream the synthetic relation to disk — it never exists in RAM."""
    mm = np.lib.format.open_memmap(path, mode="w+", dtype=np.float64,
                                   shape=(n, len(ATTRS)))
    for a in range(0, n, chunk):
        rng = np.random.default_rng(a)
        b = min(a + chunk, n)
        mm[a:b, 0] = rng.lognormal(3.0, 0.6, b - a)     # value
        mm[a:b, 1] = rng.uniform(0.2, 9.0, b - a)       # weight
        mm[a:b, 2] = rng.uniform(0.1, 4.0, b - a)       # volume
    mm.flush()


def main():
    n = 1_000_000
    tmp = tempfile.mkdtemp(prefix="pq_example_")
    path = os.path.join(tmp, "products.npy")
    print(f"writing {n} rows -> {path}")
    write_relation(path, n)

    rel = MemmapRelation.from_npy(path, ATTRS)

    # SELECT PACKAGE(*) FROM products REPEAT 0
    # SUCH THAT 10 <= COUNT(*) <= 30
    #       AND SUM(weight) <= 60 AND SUM(volume) BETWEEN 18 AND 22
    # MAXIMIZE SUM(value)
    query = PackageQuery(
        objective_attr="value", maximize=True,
        constraints=(
            Constraint(None, 10, 30),
            Constraint("weight", hi=60.0),
            Constraint("volume", lo=18.0, hi=22.0),
        ))

    relation_mod.reset_peak_resident()
    eng = PackageQueryEngine(rel, ATTRS, d_f=50, alpha=10_000, seed=0,
                             memory_rows=200_000, chunk_rows=100_000)
    eng.partition()     # streamed: bucketed DLV under the memory budget
    print(f"hierarchy: {[l.size for l in eng.hierarchy.layers]} "
          f"(partitioned in {eng.partition_time_s:.1f}s, "
          f"backend=bucketing)")

    res = eng.solve(query)
    assert res.feasible and query.check_package(rel, res.idx, res.mult)
    w = rel.gather_rows(res.idx, ("weight", "volume"))
    print(f"Progressive Shading: {int(res.mult.sum())} tuples, "
          f"value={res.obj:.1f}  [{res.status}]")
    print(f"  weight={w['weight'] @ res.mult:.1f} <= 60, "
          f"volume={w['volume'] @ res.mult:.2f} in [18, 22]")
    print(f"peak relation-resident rows: "
          f"{relation_mod.peak_resident_rows()} (of {n} total)")


if __name__ == "__main__":
    main()
