"""Serve a small model with batched requests admitted by the package-query
scheduler (the paper's technique as serving admission control).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main


def main():
    serve_main(["--arch", "qwen2-1.5b-smoke", "--requests", "32",
                "--ticks", "8", "--max-batch", "8"])


if __name__ == "__main__":
    main()
