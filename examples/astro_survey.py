"""The paper's running example (§1): pick night-sky regions that may hold
unseen quasars — average brightness above a threshold, total red shift in a
band, maximise combined quasar log-likelihood — swept across the paper's
hardness levels, with SketchRefine as the baseline.

    PYTHONPATH=src python examples/astro_survey.py
"""
import numpy as np

from repro.core.engine import PackageQueryEngine
from repro.core.hardness import column_stats, instantiate, QueryTemplate, BoundSpec
from repro.core.paql import Constraint, PackageQuery


def main():
    rng = np.random.default_rng(7)
    n = 40_000
    regions = {
        "quasar_ll": rng.normal(-0.03, 0.02, n),      # log-likelihood
        "brightness": rng.gamma(4.0, 2.0, n),
        "redshift": rng.normal(1.55, 0.35, n),
        "explored": (rng.random(n) < 0.35).astype(np.float64),
    }
    regions["unexplored"] = 1.0 - regions["explored"]
    attrs = ["quasar_ll", "brightness", "redshift"]

    # SELECT PACKAGE(*) FROM Regions WHERE explored='false'
    # SUCH THAT COUNT(*) = 10 AND AVG(brightness) >= 8
    #       AND SUM(redshift) BETWEEN 14 AND 17
    # MAXIMIZE SUM(quasar_ll)
    query = PackageQuery(
        objective_attr="quasar_ll", maximize=True,
        constraints=(
            Constraint(None, 10, 10),
            Constraint("brightness", lo=0.0, avg_target=8.0),  # AVG >= 8
            Constraint("redshift", lo=14.0, hi=17.0),
        ),
        predicate_attr="unexplored")   # local predicate (Appendix E)

    eng = PackageQueryEngine(regions, attrs, d_f=25, alpha=2500, seed=0)
    eng.partition()
    res = eng.solve(query)
    print(f"regions package: feasible={res.feasible}")
    if res.feasible:
        idx = res.idx
        print(f"  {len(idx)} regions, sum log-lik={res.obj:.4f}")
        print(f"  avg brightness={regions['brightness'][idx].mean():.2f} >= 8")
        print(f"  sum redshift={regions['redshift'][idx].sum():.2f} in [14,17]")
        assert np.all(regions["explored"][idx] == 0.0), "local predicate!"
        print("  all selected regions unexplored (local predicate holds)")

    # hardness sweep on the same relation (paper §4.1 machinery)
    tmpl = QueryTemplate(
        name="astro", objective_attr="quasar_ll", maximize=True,
        count_lo=10, count_hi=30,
        bounds=(BoundSpec("brightness", "ge"), BoundSpec("redshift",
                                                         "between")))
    stats = column_stats(regions, attrs)
    print("\nhardness sweep (PS vs SketchRefine solve):")
    for h in (1, 3, 5, 7, 9):
        q = instantiate(tmpl, stats, h)
        ps = eng.solve(q)
        sr = eng.solve_sketchrefine(q)
        print(f"  h={h}: PS={'Y' if ps.feasible else 'n'} "
              f"SR={'Y' if sr.feasible else 'n'}"
              + (f"  obj={ps.obj:.4f}" if ps.feasible else ""))


if __name__ == "__main__":
    main()
